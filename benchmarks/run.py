"""Benchmark aggregator: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,wall_s,headline`` CSV at the end.  --full uses paper-scale
table sizes (slower); the default is a reduced but structurally identical
configuration (orderings, not absolute numbers, are the claims).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--waves", type=int, default=None)
    args = ap.parse_args(argv)

    from benchmarks import (abort_rates, auto_granularity, fig2_ycsb,
                            fig3_tpcc, open_loop)
    from benchmarks.common import one

    results = []

    def timed(name, fn, argv):
        t0 = time.time()
        rows = fn(argv)
        results.append((name, time.time() - t0, rows))
        return rows

    waves = args.waves or (300 if args.full else 150)
    full = ["--full"] if args.full else []

    print("== Fig 2: YCSB coarse/fine ==", flush=True)
    r2 = timed("fig2_ycsb", fig2_ycsb.main, ["--waves", str(waves)] + full)
    print("\n== Fig 3: TPC-C coarse/fine ==", flush=True)
    r3 = timed("fig3_tpcc", fig3_tpcc.main,
               ["--waves", str(waves), "--ratios"] + full)
    print("\n== Abort rates (section 4.3) ==", flush=True)
    ra = timed("abort_rates", abort_rates.main,
               ["--waves", str(waves)] + full)
    print("\n== Auto-granularity (beyond paper) ==", flush=True)
    rg = timed("auto_granularity", auto_granularity.main,
               ["--waves", str(waves)])
    print("\n== Open-loop load-latency (beyond paper) ==", flush=True)
    ro = timed("open_loop", open_loop.main, ["--waves", str(waves)])

    print("\n== CSV summary ==")
    print("name,wall_s,headline")
    occ128f = one(r3, cc="occ", granularity=1, lanes=128)["throughput"]
    tic128f = one(r3, cc="tictoc", granularity=1, lanes=128)["throughput"]
    peak = max(r["goodput"] for r in ro)
    heads = {
        "fig2_ycsb": "see orderings above",
        "fig3_tpcc": f"OCCfine/TicTocfine@128={occ128f/tic128f:.2f}x",
        "abort_rates": "see table above",
        "auto_granularity": "see recovery above",
        "open_loop": f"peak goodput={peak:.2f} txn/us",
    }
    for name, wall, _rows in results:
        print(f"{name},{wall:.1f},{heads[name]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
