"""Paper Figure 2: YCSB-like workload, high contention (Zipf theta=0.9,
50% writes), coarse (2a) vs fine (2b) timestamps, throughput vs threads.

    PYTHONPATH=src python -m benchmarks.fig2_ycsb [--full] [--json out.json]

Validated orderings (paper section 4.2):
  2a: TicToc starts above OCC at low threads, falls below OCC at high
      threads (rts-extension CAS contention); SwissTM/Adaptive/2PL
      uniformly below OCC.
  2b: all mechanisms improve; OCC and SwissTM gain the most.
"""
from __future__ import annotations

import argparse

from benchmarks.common import LANES, save_rows, sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 10M keys (slower)")
    ap.add_argument("--waves", type=int, default=300)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--json", default="reports/fig2_ycsb.json")
    args = ap.parse_args(argv)

    n_keys = 10_000_000 if args.full else 1_000_000
    print(f"# Fig 2a (coarse) + 2b (fine), {n_keys} keys "
          f"[{args.backend} backend, one jitted grid]")
    rows = sweep("ycsb", waves=args.waves, n_keys=n_keys,
                 backend=args.backend, warm=True)
    save_rows(rows, args.json)

    # ordering checks
    from benchmarks.common import one
    hiT = max(LANES)
    occ_hi = one(rows, cc="occ", granularity=0, lanes=hiT)["throughput"]
    tic_hi = one(rows, cc="tictoc", granularity=0, lanes=hiT)["throughput"]
    occ_lo = one(rows, cc="occ", granularity=0, lanes=LANES[0])["throughput"]
    tic_lo = one(rows, cc="tictoc", granularity=0,
                 lanes=LANES[0])["throughput"]
    print(f"2a: TicToc/OCC at T={LANES[0]}: {tic_lo/occ_lo:.2f}x  "
          f"at T={hiT}: {tic_hi/occ_hi:.2f}x "
          f"(paper: >1 at low T, <1 at high T)")
    for cc in ("2pl", "swisstm", "adaptive"):
        r = one(rows, cc=cc, granularity=0, lanes=hiT)["throughput"]
        print(f"2a: {cc}/OCC at T={hiT}: {r/occ_hi:.2f}x (paper: <1)")
    for cc in ("occ", "swisstm", "tictoc", "2pl", "adaptive", "mvcc",
               "mvocc"):
        c = one(rows, cc=cc, granularity=0, lanes=hiT)["throughput"]
        f = one(rows, cc=cc, granularity=1, lanes=hiT)["throughput"]
        print(f"2b: {cc} fine/coarse at T={hiT}: {f/c:.2f}x (paper: >1)")
    # Beyond-paper: granularity still matters when readers never block —
    # YCSB's random columns put write-write pairs in different groups, so
    # the MV mechanisms' per-group first-committer-wins keeps the fine
    # advantage.  (Read-only abort rates live in benchmarks/abort_rates.py,
    # which runs the mix that actually has read-only clients.)
    mvc = one(rows, cc="mvcc", granularity=0, lanes=hiT)["throughput"]
    mvf = one(rows, cc="mvcc", granularity=1, lanes=hiT)["throughput"]
    print(f"mv: mvcc fine/coarse at T={hiT}: {mvf/mvc:.2f}x "
          "(write-write resolution stays per-group)")
    return rows


if __name__ == "__main__":
    main()
