"""Interval read-sets: fine vs coarse abort rates and goodput as the
scan mix grows (ISSUE 10 — the phantom-protection cost of timestamp
granularity).

    PYTHONPATH=src python -m benchmarks.scan_mix [--json out.json]

Fig2-style rows over a YCSB-E-like mix: ``--scan-frac`` of the
transactions carry one interval READ of ``scan_len`` consecutive keys,
validated at commit by the ``iterate_validate`` pass (phantom
protection; DESIGN.md section 13).  Two sweeps:

  (a) scan FRACTION at a fixed length — how fast each granularity's
      phantom-abort bill grows as scans enter the mix;
  (b) scan LENGTH at a fixed fraction — coarse bucket-interval claims
      pay for the whole bucket expansion of the interval, fine
      per-gap timestamps only for the keys actually read.

Validated orderings printed per point:
  - coarse phantom aborts >= fine phantom aborts (bucket claims
    over-approximate the interval; the paper's granularity gap, now on
    the scan axis);
  - fine goodput >= coarse goodput on every scan mix;
  - mvcc aborts ZERO phantoms (snapshot scans read a consistent cut —
    SI admits phantoms by design) while mvocc, which re-validates, pays.

Rows carry ``scan_frac``/``scan_len`` next to the standard bench fields
(abort_causes["phantom"], goodput, max_extent), so the dashboard can
slice the scan axis like any other grid dimension.
"""
from __future__ import annotations

import argparse

from benchmarks.common import one, save_rows, sweep

CCS = ["occ", "tictoc", "mvcc", "mvocc"]
LANES = [64]
SCAN_FRACS = (0.1, 0.3, 0.5)
SCAN_LENS = (4, 16, 64)


def _scan_rows(waves, n_keys, backend, *, scan_frac, scan_len, lanes,
               open_loop):
    kw = {}
    if open_loop:
        # Offered load at 3/4 of the lane width keeps the admission queue
        # busy without saturating it — goodput then reflects abort-driven
        # retries, not queue overflow.
        kw["arrival_rate"] = 0.75 * max(lanes)
    rows = sweep("ycsb", ccs=CCS, lanes=lanes, waves=waves, n_keys=n_keys,
                 backend=backend, warm=True, quiet=True,
                 scan_frac=scan_frac, scan_len=scan_len, **kw)
    for r in rows:
        r["scan_frac"] = scan_frac
        r["scan_len"] = scan_len
    return rows


def _report(rows, axis, value, lanes):
    for cc in CCS:
        c = one(rows, cc=cc, granularity=0, lanes=lanes)
        f = one(rows, cc=cc, granularity=1, lanes=lanes)
        cp, fp = (r["abort_causes"]["phantom"] for r in (c, f))
        line = (f"  {axis}={value:<5g} {cc:7s} phantoms "
                f"coarse={cp:6d} fine={fp:6d}  "
                f"abort {100 * c['abort_rate']:6.2f}% -> "
                f"{100 * f['abort_rate']:6.2f}%")
        if "goodput" in c:
            line += (f"  goodput {c['goodput']:7.3f} -> "
                     f"{f['goodput']:7.3f} txn/us")
        else:
            line += (f"  thpt {c['throughput']:7.3f} -> "
                     f"{f['throughput']:7.3f} txn/us")
        print(line)
        if cc == "mvcc":
            assert cp == fp == 0, "snapshot scans admit phantoms (SI)"
        else:
            assert cp >= fp, (cc, "coarse bucket claims over-approximate")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=200)
    ap.add_argument("--n-keys", type=int, default=100_000)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--closed-loop", action="store_true",
                    help="skip the open-loop front-end (rows then carry "
                         "throughput instead of goodput)")
    ap.add_argument("--json", default="reports/scan_mix.json")
    args = ap.parse_args(argv)
    open_loop = not args.closed_loop

    rows = []
    print(f"# scan-fraction sweep (scan_len=16, T={LANES[0]}, "
          f"{args.backend} backend)")
    for sf in SCAN_FRACS:
        r = _scan_rows(args.waves, args.n_keys, args.backend,
                       scan_frac=sf, scan_len=16, lanes=LANES,
                       open_loop=open_loop)
        _report(r, "frac", sf, LANES[0])
        rows += r
    print(f"# scan-length sweep (scan_frac=0.25, T={LANES[0]})")
    for sl in SCAN_LENS:
        r = _scan_rows(args.waves, args.n_keys, args.backend,
                       scan_frac=0.25, scan_len=sl, lanes=LANES,
                       open_loop=open_loop)
        _report(r, "len", sl, LANES[0])
        rows += r
    save_rows(rows, args.json)
    return rows


if __name__ == "__main__":
    main()
