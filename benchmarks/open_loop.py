"""Beyond-paper: open-loop traffic — goodput and time-to-commit latency
vs offered load (DESIGN.md section 11).

The paper's experiments are closed-loop (every thread always has a
transaction; aborts retry in place).  This benchmark drives the same
engine open-loop: Poisson arrivals queue for admission
(core/admission.py) and aborts re-enqueue with a bounded incarnation
counter, so the figure reads as a classic load-latency curve — goodput
(unique committed txns per simulated us) saturates at the closed-loop
capacity while p50/p99 time-to-commit (waves from first admission to
commit) blows up past the knee.  Fine-granularity timestamps move the
knee right for both occ and mvcc: higher sustainable load at the same
latency, the open-loop restatement of the paper's throughput claim.

    PYTHONPATH=src python -m benchmarks.open_loop [--json out.json]
"""
from __future__ import annotations

import argparse

from benchmarks.common import save_rows, sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=200)
    ap.add_argument("--lanes", type=int, default=64)
    ap.add_argument("--rates", type=float, nargs="+", default=None,
                    help="offered loads (expected arrivals/wave); default "
                         "0.25/0.5/0.75/1.0x the lane width")
    ap.add_argument("--n-keys", type=int, default=1_000_000)
    ap.add_argument("--max-incarnations", type=int, default=8)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--json", default="reports/open_loop.json")
    ap.add_argument("--trace", nargs="?", const="reports/open_loop_trace"
                    ".json", default=None, metavar="PATH",
                    help="export ONE combined Chrome-trace timeline across "
                         "all offered loads (analysis/trace.py; rows are "
                         "labeled cc/gran/rate) — REPRO_TRACE=1 also "
                         "enables it")
    args = ap.parse_args(argv)

    import os
    trace_path = args.trace
    if trace_path is None:
        env = os.environ.get("REPRO_TRACE", "")
        if env and env != "0":
            trace_path = (env if env not in ("1", "true")
                          else "reports/open_loop_trace.json")

    T = args.lanes
    rates = args.rates or [0.25 * T, 0.5 * T, 0.75 * T, 1.0 * T]
    rows = []
    traced = []
    for rate in rates:
        # One jitted sweep per offered load (the arrival rate is part of
        # the compiled scan); occ + mvcc at both granularities per sweep.
        got = sweep("ycsb", ccs=["occ", "mvcc"], lanes=[T],
                    waves=args.waves, n_keys=args.n_keys,
                    backend=args.backend, quiet=True, warm=True,
                    arrival_rate=rate, queue_cap=4 * T,
                    max_incarnations=args.max_incarnations,
                    per_wave=bool(trace_path),
                    return_points=bool(trace_path))
        if trace_path:
            got, points = got
            traced += [(rate, p) for p in points]
        for r in got:
            r["arrival_rate"] = rate
        rows += got
        for r in got:
            print(f"  rate={rate:6.1f} {r['cc']:5s} "
                  f"{'fine' if r['granularity'] else 'coarse'}: "
                  f"goodput={r['goodput']:7.3f} txn/us  "
                  f"p50={max(r['p50_ttc_waves']):3g} "
                  f"p99={max(r['p99_ttc_waves']):3g} waves  "
                  f"dropped={r['inc_drops']}")
    save_rows(rows, args.json)
    if trace_path:
        # One combined timeline: every (offered load x cc x granularity)
        # grid point is its own process row on the simulated-time axis.
        from repro.core import types as t
        from repro.analysis.trace import point_events, validate_chrome_trace
        import json as _json
        events, pid = [], 0
        for rate, p in traced:
            pid += 1
            label = (f"{t.CC_NAMES.get(p.cc, p.cc)}/"
                     f"{'fine' if p.granularity else 'coarse'}/"
                     f"rate{rate:g}")
            events += point_events(label, pid, p.per_wave_commits,
                                   p.per_wave_aborts, p.per_wave_us,
                                   p.per_wave_causes)
        trace = {"traceEvents": events, "displayTimeUnit": "ms",
                 "otherData": {"source": "repro open-loop wave trace",
                               "time_axis": "simulated microseconds"}}
        errs = validate_chrome_trace(trace)
        assert not errs, errs
        os.makedirs(os.path.dirname(trace_path) or ".", exist_ok=True)
        with open(trace_path, "w") as f:
            _json.dump(trace, f)
        print(f"[saved] {trace_path} ({pid} trace rows)")

    # The headline ordering: at the highest offered load, fine granularity
    # sustains more goodput than coarse for both mechanisms.
    from benchmarks.common import one
    hi = rates[-1]
    picked = [r for r in rows if r["arrival_rate"] == hi]
    for cc in ("occ", "mvcc"):
        g0 = one(picked, cc=cc, granularity=0)["goodput"]
        g1 = one(picked, cc=cc, granularity=1)["goodput"]
        print(f"at rate={hi:g}: {cc} fine/coarse goodput = {g1/g0:.2f}x "
              "(expected > 1 under contention)")
    return rows


if __name__ == "__main__":
    main()
