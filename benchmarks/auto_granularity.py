"""Beyond-paper: auto-granularity OCC (the paper's section-5 sketch).

Starts coarse everywhere; promotes records with false-conflict evidence to
fine-grained timestamps.  Success = recovers manual-fine OCC throughput on
TPC-C without annotations.
"""
from __future__ import annotations

import argparse

from benchmarks.common import one, save_rows, sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=400)
    ap.add_argument("--lanes", type=int, nargs="+", default=[64, 128])
    ap.add_argument("--json", default="reports/auto_granularity.json")
    args = ap.parse_args(argv)

    rows = []
    rows += sweep("tpcc", ccs=["occ"], lanes=args.lanes, grans=(0, 1),
                  waves=args.waves, scale=1.0)
    rows += sweep("tpcc", ccs=["autogran"], lanes=args.lanes, grans=(0,),
                  waves=args.waves, scale=1.0)
    save_rows(rows, args.json)

    for T in args.lanes:
        coarse = one(rows, cc="occ", granularity=0, lanes=T)["throughput"]
        fine = one(rows, cc="occ", granularity=1, lanes=T)["throughput"]
        auto = one(rows, cc="autogran", granularity=0,
                   lanes=T)["throughput"]
        rec = (auto - coarse) / max(fine - coarse, 1e-9)
        print(f"T={T:4d}: coarse {coarse:.3f}  auto {auto:.3f}  "
              f"fine {fine:.3f}  -> auto recovers {100*rec:.0f}% of the "
              f"fine-granularity gain")
    return rows


if __name__ == "__main__":
    main()
